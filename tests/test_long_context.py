"""Long-context serving trio (DESIGN.md §17): chunked prefill,
retirement-aware admission, per-group pool sizing.

The ledger property test is hypothesis-based (skipped when hypothesis is
not installed, via the conftest stub): random ragged chunked appends,
retirements, COW-inducing shared retains, and frees on a mixed
global/window stack — `check_invariants` (which now carries the §17
ledger invariant: net draws never exceed the reservation) must hold
after every single step, and a live-bound-sized pool must never raise
MemoryError (i.e. admission never under-reserves).

The pinned regression test is the tentpole's headline acceptance: a
long-prompt trace that deadlocks at head-of-line on the uniform pool
admits and drains under per-group sizing + chunked prefill, tokens
bit-exact vs the single-shot path on a big pool.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.configs import get_config
from repro.models import init_lm
from repro.serve import (
    ContinuousBatcher,
    PagedKVCache,
    Request,
    ServeConfig,
    ServeEngine,
)

ARCH = "gemma3-27b"  # 5:1 window(8):global smoke stack — both group kinds


@pytest.fixture(scope="module")
def model():
    # fp32 activations so greedy-argmax token parity across differently
    # compiled paths is meaningful (same rationale as test_paged_cache)
    cfg = dataclasses.replace(get_config(ARCH, smoke=True), dtype="float32")
    params = init_lm(jax.random.PRNGKey(0), cfg)
    return cfg, params


def _prompt(uid: int, t: int, vocab: int) -> jnp.ndarray:
    return jax.random.randint(
        jax.random.fold_in(jax.random.PRNGKey(11), uid), (t,), 0, vocab
    ).astype(jnp.int32)


# ---------------------------------------------------------------------------
# live-bound / sizing arithmetic
# ---------------------------------------------------------------------------

def test_live_bound_and_auto_sizing(model):
    cfg, _ = model
    bs, chunk = 4, 8
    pc = PagedKVCache(cfg, n_slots=2, max_len=64, block_size=bs,
                      prefill_chunk=chunk, group_blocks="auto")
    by_window = {p.window: p for p in pc.pools}
    g, w = by_window[None], by_window[cfg.sliding_window]
    # global group: no retirement, no live bound, uniform pool
    assert g.live_bound is None
    assert g.n_blocks == 1 + 2 * pc.max_blocks_per_slot
    # windowed group: ceil(W/bs) + (chunk_blocks + 1) default slack
    expect = -(-cfg.sliding_window // bs) + (chunk // bs + 1)
    assert w.live_bound == expect
    assert w.n_blocks == 1 + 2 * expect
    # draws_for caps at the bound; the global promise is the worst case
    assert pc.draws_for(64, live_bound=w.live_bound) == expect
    assert pc.draws_for(64, live_bound=None) == 16
    # reservation succeeds for a prompt the uniform windowed pool could
    # never promise (16 draws/slot against a 10-page pool)
    assert pc.reserve_slot(0, 64)
    assert pc.reserve_slot(1, 64)
    pc.check_invariants()
    assert pc.provisioned_page_bytes() < PagedKVCache(
        cfg, n_slots=2, max_len=64, block_size=bs
    ).provisioned_page_bytes()


def test_auto_sizing_requires_chunking(model):
    cfg, _ = model
    with pytest.raises(ValueError, match="prefill_chunk"):
        PagedKVCache(cfg, n_slots=2, max_len=64, block_size=4,
                     group_blocks="auto")


def test_chunked_appends_stay_within_live_bound(model):
    """Drive one slot through a 64-token prompt in 8-token chunks plus
    decode appends: the windowed group's net draws never exceed the
    promised live bound, retirement draws the ledger down, and the
    shrunk pool never runs dry."""
    cfg, _ = model
    bs, chunk = 4, 8
    pc = PagedKVCache(cfg, n_slots=2, max_len=80, block_size=bs,
                      prefill_chunk=chunk, group_blocks="auto")
    w = next(p for p in pc.pools if p.window is not None)
    assert pc.reserve_slot(0, 80)
    start = 0
    while start < 64:
        pc.begin_append(0, start, min(chunk, 64 - start))
        start = min(start + chunk, 64)
        pc.lengths[0] = start
        pc.check_invariants()
        assert w._drawn[0] <= w._reserved[0]
        assert w.live_pages(0) <= w.live_bound
    for _ in range(16):
        pc.append_position(0)
        pc.check_invariants()
        assert w.live_pages(0) <= w.live_bound
    # retirement recycled the slid-out pages back to the free list
    assert w.pages_retired > 0
    assert w.n_free > 0


# ---------------------------------------------------------------------------
# hypothesis: the ledger never over- or under-reserves
# ---------------------------------------------------------------------------

def _ledger_step(pc, data, state):
    """One random mutation of the admission/append/retire/COW state
    machine, mirroring the scheduler's real sequences: reserve-then-
    attach (prefix hit with a possibly mid-block cached length, COW
    reserved via n_cow), chunk-bounded begin_append, publish-on-finish
    (full blocks only — exactly what PrefixIndex.publish retains), and
    free. `state` carries per-slot totals and the published chain."""
    totals, chain, ext, idle, running = (
        state["totals"], state["chain"], state["ext"], state["idle"],
        state["running"],
    )
    bs, chunk = pc.block_size, pc.prefill_chunk
    max_len = pc.max_blocks_per_slot * bs
    if idle and data.draw(st.booleans(), label="admit"):
        i = sorted(idle)[0]
        total = data.draw(st.integers(1, max_len), label="total")
        plan, n_cached, shared, cow = None, 0, 0, 0
        nbh = data.draw(st.integers(0, len(chain)), label="attach")
        if nbh and nbh * bs < total:
            # cached length may end MID-BLOCK (a hit capped at t-1):
            # the first suffix append then COWs the attached block
            n_cached = data.draw(
                st.integers((nbh - 1) * bs + 1, min(nbh * bs, total - 1)),
                label="n_cached")
            plan = pc.plan_attach(chain[:nbh], n_cached)
            if plan is not None:
                shared, cow = pc.attach_plan_counts(
                    plan, needs_cow=n_cached % bs != 0)
        if pc.reserve_slot(i, total, n_shared=shared, n_cow=cow):
            if plan is not None:
                pc.attach_chain(i, plan)
                pc.lengths[i] = n_cached
            totals[i] = total
            idle.discard(i)
            running.add(i)
        return
    if not running:
        return
    i = data.draw(st.sampled_from(sorted(running)), label="slot")
    length = int(pc.lengths[i])
    if length >= totals[i]:
        if data.draw(st.booleans(), label="publish") and not chain:
            for j in range(length // bs):
                pages = pc.slot_block_pages(i, j)
                if not pages:
                    break
                for gid, page in pages.items():
                    pc.retain(page, gid)
                    ext.setdefault(gid, {})
                    ext[gid][page] = ext[gid].get(page, 0) + 1
                chain.append(pages)
        pc.free_slot(i)
        running.discard(i)
        idle.add(i)
    else:
        n = min(data.draw(st.integers(1, chunk), label="append"),
                totals[i] - length)
        pc.begin_append(i, length, n)  # retires, grows, COWs as needed
        pc.lengths[i] = length + n


@given(st.data())
@settings(max_examples=25, deadline=None)
def test_reservation_ledger_property(data):
    """Random ragged chunked appends + retirements + COW-carrying prefix
    attaches + frees on the mixed stack: invariants (incl. the §17
    ledger bound: net draws never exceed the reservation) hold after
    EVERY mutation, and the auto-sized pool never raises MemoryError —
    the live-bound reservation is simultaneously sufficient (no
    under-reserve) and honored (no over-draw)."""
    cfg = get_config(ARCH, smoke=True)
    bs = data.draw(st.sampled_from([2, 4]), label="block_size")
    chunk = bs * data.draw(st.integers(1, 3), label="chunk_blocks")
    n_slots = data.draw(st.integers(1, 3), label="n_slots")
    max_len = bs * data.draw(st.integers(8, 16), label="max_blocks")
    pc = PagedKVCache(cfg, n_slots=n_slots, max_len=max_len,
                      block_size=bs, prefill_chunk=chunk,
                      group_blocks="auto")
    state = {"totals": [0] * n_slots, "chain": [], "ext": {},
             "idle": set(range(n_slots)), "running": set()}
    for _ in range(40):
        _ledger_step(pc, data, state)
        pc.check_invariants(
            external_refs=state["ext"] if state["ext"] else None)
        for p in pc.pools:
            for s, r in p._reserved.items():
                assert p._drawn[s] <= r, (p.gid, s, p._drawn[s], r)
            if p.live_bound is not None:
                # +1: the attached mid-block COW page is resident on top
                # of the slot's own live window
                for s in range(n_slots):
                    assert p.live_pages(s) <= p.live_bound + 1, \
                        (p.gid, s)


# ---------------------------------------------------------------------------
# the pinned long-prompt regression (tentpole headline acceptance)
# ---------------------------------------------------------------------------

def _batcher(cfg, params, **kw):
    cb = ContinuousBatcher(cfg, params, n_slots=2, cache_len=96,
                           prompt_len=None, paged=True, block_size=4, **kw)
    # head-of-queue long prompt behind nothing: the uniform pool must
    # promise ceil(total/bs) = 20 windowed draws/slot it can never hold
    cb.submit(Request(uid=0, prompt=_prompt(0, 76, cfg.vocab_size),
                      max_new_tokens=4))
    for uid in (1, 2, 3):
        cb.submit(Request(uid=uid, prompt=_prompt(uid, 6, cfg.vocab_size),
                          max_new_tokens=4))
    return cb


def test_long_prompt_deadlocks_on_uniform_pool(model):
    cfg, params = model
    # 11 pages per group: plenty for the short requests, short of the
    # long prompt's 20-block worst-case windowed promise
    cb = _batcher(cfg, params, n_blocks=12)
    # admission is FIFO-among-admissible, so the short requests drain
    # first; the deadlock fires once only the long prompt remains
    with pytest.raises(RuntimeError, match=(
        r"deadlock at tick \d+.*pools:.*g0.*draws promised"
        r".*head-of-queue uid=0 needs 79 tokens"
        r".*per-group draw deficit:.*g\d+:-\d+"
    )):
        cb.run_until_drained()


def test_long_prompt_admits_with_chunking_and_sizing(model):
    cfg, params = model
    ref = _batcher(cfg, params).run_until_drained()
    # per-group sizing: the global group keeps its full provisioning
    # (nothing retires there) while the windowed groups keep the SAME
    # 11-page budget that just deadlocked — chunked prefill drops the
    # windowed promise to ceil(8/4) + 3 = 5 draws and the trace drains,
    # tokens bit-exact vs single-shot prefill on an ample pool
    probe = _batcher(cfg, params).pcache
    windowed = {p.gid: 12 for p in probe.pools if p.window is not None}
    cb = _batcher(cfg, params, prefill_chunk=8, group_blocks=windowed)
    got = cb.run_until_drained()
    assert got == ref
    # and with per-group sizing the windowed pool physically shrinks
    auto = _batcher(cfg, params, prefill_chunk=8, group_blocks="auto")
    assert auto.run_until_drained() == ref
    w = next(p for p in auto.pcache.pools if p.window is not None)
    g = next(p for p in auto.pcache.pools if p.window is None)
    assert w.n_blocks < g.n_blocks
    assert auto.pcache.provisioned_page_bytes() < \
        ContinuousBatcher(cfg, params, n_slots=2, cache_len=96,
                          prompt_len=None, paged=True, block_size=4
                          ).pcache.provisioned_page_bytes()


def test_chunked_prefill_interleaves_with_decode(model):
    """A long prompt arriving mid-stream must NOT stall running decodes:
    while its chunks prefill one per tick, the already-active short
    request keeps emitting tokens (no head-of-line stall)."""
    cfg, params = model
    cb = ContinuousBatcher(cfg, params, n_slots=2, cache_len=96,
                           prompt_len=None, paged=True, block_size=4,
                           prefill_chunk=8)
    cb.submit(Request(uid=0, prompt=_prompt(0, 6, cfg.vocab_size),
                      max_new_tokens=12))
    cb.step()  # uid 0 active and decoding
    cb.submit(Request(uid=1, prompt=_prompt(1, 40, cfg.vocab_size),
                      max_new_tokens=2))
    def tokens0():
        if 0 in cb.finished:
            return len(cb.finished[0])
        req = next(s for s in cb.slots if s is not None and s.uid == 0)
        return len(req.generated)

    progress = []
    for _ in range(30):
        cb.step()
        progress.append(tokens0())
        if 1 not in cb._chunk_pos:
            break
    else:
        pytest.fail("long prompt never finished chunking")
    # uid 0 decoded on ticks where uid 1 was still mid-chunk
    assert progress[-1] > 1
    results = cb.run_until_drained()
    ref = ContinuousBatcher(cfg, params, n_slots=2, cache_len=96,
                            prompt_len=None, paged=True, block_size=4)
    ref.submit(Request(uid=0, prompt=_prompt(0, 6, cfg.vocab_size),
                       max_new_tokens=12))
    ref.step()
    ref.submit(Request(uid=1, prompt=_prompt(1, 40, cfg.vocab_size),
                       max_new_tokens=2))
    assert results == ref.run_until_drained()


def test_engine_chunked_prefill_bit_exact(model):
    cfg, params = model
    prompts = jnp.stack([_prompt(u, 24, cfg.vocab_size) for u in range(2)])
    base = ServeEngine(cfg, params, ServeConfig(
        max_cache_len=64, max_new_tokens=4, paged=True, block_size=4))
    chunked = ServeEngine(cfg, params, ServeConfig(
        max_cache_len=64, max_new_tokens=4, paged=True, block_size=4,
        prefill_chunk=8))
    a = base.generate(prompts, jax.random.PRNGKey(3))
    b = chunked.generate(prompts, jax.random.PRNGKey(3))
    assert jnp.array_equal(a, b)


def test_scheduler_validates_chunk_knobs(model):
    cfg, params = model
    with pytest.raises(ValueError, match="paged"):
        ContinuousBatcher(cfg, params, n_slots=2, cache_len=32,
                          prompt_len=8, paged=False, prefill_chunk=8)
