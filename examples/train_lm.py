"""End-to-end driver (deliverable b): train a ~100M-param qwen2-family LM
for a few hundred steps with the full production substrate — sharded
params, fault-tolerant checkpointing, prefetching data pipeline,
straggler monitor — scaled to this CPU host.

Run:  PYTHONPATH=src python examples/train_lm.py [--steps 300]
(~100M params; on CPU this takes a while — use --d-model 256 for a
faster demonstration with the identical code path.)
"""

import argparse
import dataclasses
import tempfile

import jax

from repro.configs import get_config
from repro.data.synthetic import DataConfig
from repro.models import init_lm
from repro.models.transformer import count_params
from repro.optim import AdamWConfig
from repro.train import Trainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--d-model", type=int, default=512)
    ap.add_argument("--layers", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--vocab", type=int, default=8192)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = dataclasses.replace(
        get_config("qwen2-1.5b"),
        n_layers=args.layers, d_model=args.d_model,
        n_heads=max(2, args.d_model // 128), n_kv_heads=max(1, args.d_model // 256),
        d_ff=args.d_model * 4, vocab_size=args.vocab, remat=False,
    )
    params = init_lm(jax.random.PRNGKey(0), cfg)
    print(f"model: {cfg.name}-mini, {count_params(params)/1e6:.1f}M params")

    data_cfg = DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq,
                          global_batch=args.batch)
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="repro_train_")
    stragglers = []
    trainer = Trainer(
        cfg, params, data_cfg, ckpt_dir,
        opt_cfg=AdamWConfig(lr=1e-3),
        trainer_cfg=TrainerConfig(total_steps=args.steps, ckpt_every=100,
                                  log_every=20),
        straggler_callback=stragglers.append,
    )
    log = trainer.run()
    first, last = log[0], log[-1]
    print(f"\nloss: {first['loss']:.3f} (step {first['step']}) -> "
          f"{last['loss']:.3f} (step {last['step']})")
    print(f"accuracy: {first['accuracy']:.3f} -> {last['accuracy']:.3f}")
    print(f"checkpoints in {ckpt_dir} (resume by re-running with --ckpt-dir)")
    if stragglers:
        print(f"straggler events: {[(e.step, round(e.step_time, 2)) for e in stragglers]}")
    assert last["loss"] < first["loss"], "training must reduce loss"


if __name__ == "__main__":
    main()
