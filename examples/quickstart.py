"""Quickstart: the paper's two layers in five minutes.

1. The FPGA side — IMAGine, bit-exact: run a GEMV on the cycle-accurate
   PIM-array simulator, check it against numpy, fit the Gold Standard
   reduction model (paper Table IX).
2. The TPU side — the adapted technique: bit-plane quantize a weight
   matrix, run the Pallas kernel (interpret mode on CPU), and see the
   bandwidth amplification that makes decode GEMV faster.

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import numpy as np
import jax.numpy as jnp

from repro.core import ImagineConfig, ImagineGemv, fit_reduction_model
from repro.core.gemv_engine import reduction_model_cycles
from repro.core.fpga_devices import DEVICES, peak_tops
from repro.kernels import ops


def fpga_side():
    print("=== 1. IMAGine (FPGA PIM simulator, bit-exact) ===")
    eng = ImagineGemv(ImagineConfig(rows=4, cols=8, lanes=8, depth=512,
                                    n_bits=8, acc_bits=24))
    rng = np.random.default_rng(0)
    w = rng.integers(-128, 128, size=(16, 64))
    x = rng.integers(-128, 128, size=(64,))
    y, cycles = eng.run_gemv(w, x)
    assert np.array_equal(y, w @ x)
    print(f"GEMV 16x64 int8: bit-exact vs numpy, {cycles} cycles "
          f"(analytic model: {eng.analytic_cycles(16, 64)})")
    u55 = DEVICES["U55"]
    print(f"U55 @ 737 MHz, 100% BRAMs: {u55.max_pe} PEs, "
          f"{peak_tops(u55.max_pe, 737.0, 8):.2f} TOPS @ int8 (paper: 0.33)")
    fit = fit_reduction_model(lambda n, p: reduction_model_cycles(n, p), 32)
    print(f"Gold Standard fit (Table IX): a={fit.a:.2f} b={fit.b:.2f} "
          f"c={fit.c:.0f}  (paper: 1.2 / 0.9 / 143) -> "
          f"{fit.interpretation()}")


def tpu_side():
    print("\n=== 2. Bit-plane GEMV (TPU adaptation) ===")
    rng = np.random.default_rng(1)
    K, M, B = 1024, 1024, 4
    w = jnp.asarray(rng.normal(size=(K, M)), jnp.float32)
    x = jnp.asarray(rng.normal(size=(B, K)), jnp.float32)
    for n_bits, group in [(8, 1), (4, 1), (8, 2)]:
        planes, scale = ops.quantize_and_pack(w, n_bits, group, impl="ref")
        y = ops.bitplane_matmul(x, planes, scale, n_bits=n_bits, group=group,
                                impl="ref")
        rel = float(jnp.linalg.norm(y - x @ w) / jnp.linalg.norm(x @ w))
        amp = (K * M * 2) / ops.packed_bytes(K, M, n_bits, group)
        tag = "bit-serial" if group == 1 else f"slice{2*group} (radix-4)"
        print(f"n_bits={n_bits} group={group} ({tag}): HBM amplification "
              f"{amp:.1f}x vs bf16, rel err {rel:.4f}")
    print("decode GEMV is HBM-bound: fewer weight bytes == faster tokens —")
    print("the paper's 'BRAM is the limit' objective, on the TPU memory system.")


if __name__ == "__main__":
    fpga_side()
    tpu_side()
