"""Serving example (deliverable b): continuous-batching decode with
PIM-resident (bit-plane quantized) weights — the paper's GEMV engine as a
first-class serving feature.

Compares dense vs 8-bit bit-serial (group=1) vs 8-bit slice4-style
(group=2, Booth-radix-4 analogue) serving: same tokens, and the packed
fraction / HBM-byte reduction that sets decode speed on the target TPU.

Run:  PYTHONPATH=src python examples/serve_pim_gemv.py

Quickstart — paged serving (DESIGN.md §8). The block-paged KV cache
lifts the dense cache's shared-prompt-length restriction: requests with
different (unpadded) prompt lengths batch together, slots refill at any
tick, and finished requests' pages recycle through a free list.

    from repro.serve import ContinuousBatcher, Request, ServeConfig, ServeEngine

    # batch engine: flip ServeConfig.paged (dense path stays the default)
    eng = ServeEngine(cfg, params, ServeConfig(max_new_tokens=8, paged=True,
                                               block_size=16))
    tokens = eng.generate(prompts)           # same greedy tokens as dense

    # continuous batching over ragged prompts (no prompt_len needed)
    cb = ContinuousBatcher(cfg, params, n_slots=4, cache_len=64,
                           paged=True, block_size=16)
    cb.submit(Request(uid=0, prompt=short_prompt, max_new_tokens=8))
    cb.submit(Request(uid=1, prompt=long_prompt, max_new_tokens=8))
    results = cb.run_until_drained()

Shared-prefix sharing (DESIGN.md §9): with `prefix=True` the batcher
indexes every served prompt's full KV pages in a radix trie; requests
opening with the same tokens map those pages refcounted into their own
block table and prefill only the uncached suffix (bit-identical greedy
tokens, far fewer prefill tokens and page draws):

    cb = ContinuousBatcher(cfg, params, n_slots=4, cache_len=64,
                           paged=True, block_size=16, prefix=True)

Telemetry (DESIGN.md §13): attach a `ServeTelemetry` to trace the
request lifecycle (TTFT/TPOT/queue-delay percentiles), per-tick pool
gauges, and per-launch streamed-byte accounting — observation only,
tokens are bit-identical and the default telemetry=None path makes
zero registry calls:

    from repro.obs import ServeTelemetry
    tel = ServeTelemetry(events_path="events.jsonl")
    cb = ContinuousBatcher(cfg, params, n_slots=4, cache_len=64,
                           paged=True, block_size=16, telemetry=tel)
    ...
    cb.run_until_drained()
    tel.latency_summary()["ttft_s"]["p99"]   # exact percentiles
    tel.registry.prometheus()                # text snapshot

CLI:  PYTHONPATH=src python -m repro.launch.serve --paged --quantize
      PYTHONPATH=src python -m repro.launch.serve --paged --prefix --metrics
Bench: PYTHONPATH=src python -m benchmarks.serve_bench   (dense vs paged)
       PYTHONPATH=src python -m benchmarks.prefix_bench  (shared prefix)
"""

import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import init_lm
from repro.models.transformer import count_params
from repro.quant.bitplane import PimQuantConfig
from repro.serve import ContinuousBatcher, Request, ServeConfig, ServeEngine


def main():
    cfg = get_config("qwen2-1.5b", smoke=True)
    params = init_lm(jax.random.PRNGKey(0), cfg)
    print(f"model: {cfg.name} (smoke), {count_params(params)/1e3:.0f}K params")
    prompts = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0, cfg.vocab_size)

    eng = ServeEngine(cfg, params, ServeConfig(max_cache_len=48, max_new_tokens=8))
    dense = eng.generate(prompts)
    print("dense tokens      :", dense[0].tolist())

    for n_bits, group, tag in [(8, 1, "bit-serial r2"), (8, 2, "slice4 / r4")]:
        e = ServeEngine(cfg, params, ServeConfig(max_cache_len=48, max_new_tokens=8))
        frac = e.quantize(PimQuantConfig(n_bits=n_bits, group=group, min_features=16))
        out = e.generate(prompts)
        agree = float(jnp.mean((out == dense).astype(jnp.float32)))
        print(f"{tag:14s} int{n_bits}: packed {frac:.0%} of param bytes, "
              f"token agreement {agree:.0%} -> {out[0].tolist()}")

    # continuous batching with quantized weights + paged KV cache:
    # ragged prompt lengths in one batch (impossible with the dense cache)
    eng.quantize(PimQuantConfig(n_bits=8, min_features=16))
    cb = ContinuousBatcher(cfg, eng.params, n_slots=2, cache_len=48,
                           paged=True, block_size=8)
    for uid, t in enumerate([8, 5, 11, 3, 8, 6]):
        cb.submit(Request(uid=uid, prompt=prompts[uid % 4][:t],
                          max_new_tokens=4))
    t0 = time.perf_counter()
    results = cb.run_until_drained()
    dt = time.perf_counter() - t0
    n_tok = sum(len(v) for v in results.values())
    print(f"\ncontinuous batching (paged): {len(results)} ragged requests, "
          f"{n_tok} tokens, {dt:.1f}s (2 slots, PIM-resident weights)")


if __name__ == "__main__":
    main()
