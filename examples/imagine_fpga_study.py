"""Reproduce the paper's analysis figures end-to-end (deliverable b):

 - Table I relative clocks and Gold Standard scores (Table VIII)
 - Fig. 1 ideal-scaling gap for RIMA vs IMAGine
 - Fig. 7 GEMV latency/exec-time across designs (ASCII plot)
 - Table IX curve fits with speed interpretations
 - the IMAGine-slice4 what-if (paper §V-G)

Run:  PYTHONPATH=src python examples/imagine_fpga_study.py
"""

from repro.core.fpga_devices import DEVICES, RIMA_SCALING_POINTS, ideal_scaling_tops, peak_tops
from repro.core.gemv_engine import reduction_model_cycles
from repro.core.gold_standard import fit_reduction_model, score_published
from repro.core.latency_models import DESIGN_MODELS, reduction_cycles_for_fit


def main():
    n_pe = DEVICES["U55"].max_pe

    print("=== Gold Standard scores (Table VIII) ===")
    for name in ("RIMA-Large", "CCB-GEMV", "CoMeFa-D-GEMM", "SPAR-2",
                 "IMAGine", "IMAGine-CB"):
        s = score_published(name)
        print(f"{name:15s} clock={s.clock_fraction:6.1%} bram={s.scaling_fraction:6.1%} "
              f"bandwidth={s.bandwidth_fraction:6.1%} gold={s.is_gold}")

    print("\n=== Fig. 1: ideal scaling vs RIMA (Stratix 10, int8) ===")
    for pt in RIMA_SCALING_POINTS:
        frac = pt["bram_fraction"]
        ideal = ideal_scaling_tops("S10", frac, 8, f_mhz=624.0)
        actual = peak_tops(int(DEVICES["S10"].max_pe * frac), pt["f_sys_mhz"], 8)
        bar = "#" * int(40 * actual / ideal)
        print(f"bram={frac:4.0%} ideal={ideal:5.2f} actual={actual:5.2f} "
              f"TOPS |{bar:<40s}| {actual/ideal:4.0%}")

    print("\n=== Fig. 7: GEMV execution time (us), int8, U55-sized array ===")
    dims = (256, 512, 1024, 2048, 4096)
    names = ("IMAGine", "IMAGine-slice4", "CCB", "CoMeFa-D", "SPAR-2")
    print(f"{'D':>6} " + " ".join(f"{n:>15s}" for n in names))
    for d in dims:
        row = []
        for n in names:
            t = DESIGN_MODELS[n].gemv_time_us(d, 8, n_pe)
            row.append(f"{t:15.1f}")
        print(f"{d:>6} " + " ".join(row))
    print("(IMAGine wins every column despite longer cycle counts than "
          "CCB/CoMeFa — clock rate dominates, the paper's central claim)")

    print("\n=== Table IX: Gold Standard curve fits (32-bit accumulation) ===")
    from repro.core.latency_models import spar2_binary_array, spar2_linear_array
    cases = {
        "SPAR-2 linear": lambda n, p: spar2_linear_array(n, p),
        "SPAR-2 binary": lambda n, p: spar2_binary_array(n, p),
        "CCB/CoMeFa":    reduction_cycles_for_fit("CCB"),
        "IMAGine":       lambda n, p: reduction_model_cycles(n, p, k=16),
    }
    print(f"{'design':15s} {'a':>6} {'b':>6} {'c':>7}  interpretation")
    for name, fn in cases.items():
        fit = fit_reduction_model(fn, 32)
        i = fit.interpretation()
        print(f"{name:15s} {fit.a:6.2f} {fit.b:6.2f} {fit.c:7.1f}  "
              f"add={i['addition']}, move={i['movement']}, gold={i['in_gold_range']}")


if __name__ == "__main__":
    main()
